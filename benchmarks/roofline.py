"""Roofline analysis (deliverable g).

Reads the dry-run artifacts (experiments/dryrun/*.json) and derives the
three roofline terms per (arch x shape x mesh). TPU v5e constants:

    peak bf16    : 197 TFLOP/s per chip
    HBM bandwidth: 819 GB/s per chip
    ICI          : ~50 GB/s per link per chip

NOTE on normalization: XLA's cost_analysis() on an SPMD-partitioned module
reports PER-DEVICE flops/bytes (verified against an analytically-sized
sharded matmul), and the optimized HLO is the per-device program, so
collective operand sizes are per-device too. Hence:

    compute_term    = flops / PEAK
    memory_term     = bytes_accessed / HBM_BW
    collective_term = collective_bytes / ICI_BW

MODEL_FLOPS (useful work) per device:
    train   : 6 * N_active * tokens / chips
    prefill : 2 * N_active * tokens / chips
    decode  : 2 * N_active * batch  / chips
The ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/padding waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_per_device(rec) -> float:
    n = rec["active_params"]
    from repro.configs import INPUT_SHAPES

    shape = INPUT_SHAPES[rec["shape"]]
    chips = rec["chips"]
    if shape.kind == "train":
        total = 6.0 * n * shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        total = 2.0 * n * shape.seq_len * shape.global_batch
    else:
        total = 2.0 * n * shape.global_batch
    return total / chips


def analyze(rec) -> dict:
    comp = rec["flops"] / PEAK_FLOPS
    mem = rec["bytes_accessed"] / HBM_BW
    coll = sum(rec["collective_bytes"].values()) / ICI_BW
    dom = max((comp, "compute"), (mem, "memory"), (coll, "collective"))[1]
    mf = model_flops_per_device(rec)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops": rec["flops"],
        "useful_ratio": mf / rec["flops"] if rec["flops"] else float("nan"),
        "collective_breakdown": rec["collective_bytes"],
    }


def load_all(dirname="experiments/dryrun"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def table(dirname="experiments/dryrun", mesh=None, variant="baseline"):
    rows = [
        analyze(r)
        for r in load_all(dirname)
        if (mesh is None or r["mesh"] == mesh)
        and (variant is None or (r.get("variant", "baseline") == variant and not r.get("zero1")))
    ]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def to_markdown(rows) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful FLOPs ratio |\n|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.3f} |"
        )
    return hdr + "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = table(args.dir, args.mesh)
    if args.markdown:
        print(to_markdown(rows))
        return
    for r in rows:
        print(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"comp={r['compute_s']:.3e} mem={r['memory_s']:.3e} "
            f"coll={r['collective_s']:.3e} dom={r['dominant']:10s} "
            f"useful={r['useful_ratio']:.3f}"
        )


if __name__ == "__main__":
    main()
