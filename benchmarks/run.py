"""Benchmark entry point: one function per paper table/figure + the kernel
microbench, the serving-runtime bench, the distortion-drift bench, and the
roofline summary. Prints ``name,us_per_call,derived`` CSV; the serving and
distortion benches also write the machine-readable ``BENCH_serving.json``
and ``BENCH_distortion.json`` artifacts.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--epochs N]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_exit_gate_jnp(rows=256, vocab=50280):
    """The gate's jnp reference path (what the Pallas kernel replaces)."""
    from repro.core.exits import gate_statistics

    z = jax.random.normal(jax.random.PRNGKey(0), (rows, vocab))
    f = jax.jit(lambda z: gate_statistics(z, 1.7))
    us = _time_call(f, z)
    traffic = rows * vocab * 4 * 3  # softmax+max+entropy: ~3 passes
    return us, f"hbm_bytes_naive={traffic}"


def bench_exit_gate_kernel(rows=256, vocab=50280):
    """Fused kernel (interpret mode on CPU -- correctness path; the derived
    column reports the single-pass HBM traffic the fusion achieves on TPU)."""
    from repro.kernels.ops import exit_gate

    z = jax.random.normal(jax.random.PRNGKey(0), (8, vocab))
    us = _time_call(lambda a: exit_gate(a, 1.7), z, iters=1, warmup=1)
    traffic = rows * vocab * 4  # one streaming pass
    return us, f"hbm_bytes_fused={traffic};traffic_cut=3.0x"


def bench_plan_gate(rows=512, c=10):
    """OffloadPlan.gate fast path: temperature states hand raw logits + T
    straight to apply_gate (kernel-routable) instead of materializing
    calibrated logits."""
    from repro.core.policy import OffloadPlan
    from repro.core.calibration import TemperatureScaling

    plan = OffloadPlan(
        p_tar=0.85, calibrators=[TemperatureScaling.from_temperature(1.7)]
    )
    z = jax.random.normal(jax.random.PRNGKey(0), (rows, c)) * 4
    f = jax.jit(lambda zz: plan.gate(zz).exit_mask)
    us = _time_call(f, z)
    return us, f"rows={rows};fastpath=temperature"


def bench_calibration_fit(n=10000, c=10):
    from repro.core.calibration import fit_temperature

    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (n, c)) * 6
    y = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, c)
    f = jax.jit(lambda z, y: fit_temperature(z, y)[0])
    us = _time_call(f, z, y)
    return us, f"n={n}"


def bench_b_alexnet_step(batch=256):
    from repro.models import convnet
    from repro.models.convnet import B_ALEXNET
    from repro.training import optim
    from repro.training.loop import make_train_step

    params = convnet.init_params(jax.random.PRNGKey(0))
    step = jax.jit(
        make_train_step(B_ALEXNET, optim.AdamWConfig(total_steps=10), remat=False)
    )
    state = optim.init(params)
    b = {
        "images": jax.random.normal(jax.random.PRNGKey(1), (batch, 32, 32, 3)),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (batch,), 0, 10),
    }
    us = _time_call(
        lambda p, s, bb: step(p, s, bb)[2]["loss"], params, state, b, iters=3
    )
    return us, f"batch={batch}"


def bench_smoke_decode(arch="qwen3-8b"):
    from repro.configs import get_smoke
    from repro.launch.serve import make_serve_step
    from repro.models import registry

    cfg = get_smoke(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    caches = registry.init_cache(cfg, 4, 128)
    step = jax.jit(make_serve_step(cfg))
    tok = jnp.ones((4, 1), jnp.int32)
    us = _time_call(
        lambda: step(params, tok, caches, jnp.int32(5))[0]["token"], iters=3
    )
    return us, f"arch={arch}-smoke"


def bench_serving_runtime(n_requests=2000, out_path="BENCH_serving.json"):
    """Event-driven serving runtime under a congested Markov link: static
    calibrated plan vs the online controller re-scoring the same
    calibrators. The scenario is repro.serving.scenarios.run_congested_markov
    -- the SAME one the acceptance test pins down -- so the benchmark and
    the test cannot drift apart. Writes BENCH_serving.json with the fully
    deterministic simulated metrics (p50/p95/p99, deadline-miss, offload,
    accuracy); the wall-clock sim throughput goes to the CSV row only."""
    from repro.core.calibration import TemperatureScaling
    from repro.core.policy import OffloadPlan
    from repro.serving.scenarios import (
        run_congested_markov,
        synthetic_cascade_logits,
    )

    n = 2048
    exits, final, y = synthetic_cascade_logits(n)
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0),
                     TemperatureScaling.from_temperature(1.0)],
    )

    def scenario(with_controller, obs=None, controller_config=None):
        t0 = time.perf_counter()
        tel = run_congested_markov(
            plan, exits, final, y,
            n_requests=n_requests, with_controller=with_controller, obs=obs,
            controller_config=controller_config,
        )
        return tel.summary(), time.perf_counter() - t0

    static, wall_s = scenario(False)
    ctrl, wall_c = scenario(True)

    # instrumentation-overhead arm: the same static scenario with the
    # FULL observability bundle (trace + audit + metrics + calibration
    # sketch) attached, median-of-3 both ways against the obs-off run.
    # Two claims ride in the artifact and CI asserts both: the obs-on
    # summaries are BIT-IDENTICAL to obs-off (zero perturbation), and
    # the wall-clock ratio stays under a documented (generous -- shared
    # CI runners are noisy) bound.
    from repro.obs import full_observability

    off_walls, on_walls = [], []
    obs_summary = None
    for _ in range(3):
        _, w = scenario(False)
        off_walls.append(w)
        obs_summary, w = scenario(False, obs=full_observability())
        on_walls.append(w)
    off_med = sorted(off_walls)[1]
    on_med = sorted(on_walls)[1]
    obs_overhead = {
        "off_wall_s": off_med,
        "on_wall_s": on_med,
        "ratio": on_med / off_med,
        "bound": 3.0,  # CI assertion; documented in docs/observability.md
        "bit_exact": obs_summary == static,
    }

    # congested-uplink compression sweep (ISSUE 10): controller arms
    # differing ONLY in the codec axis -- bytes-blind (no axis: the
    # legacy candidate table), level-0-only (identity codec: MUST
    # reproduce the bytes-blind run bit-exactly), and compression-aware
    # (levels 0/1/2 priced per candidate). Each arm carries a metrics
    # registry so uplink bytes are the runtime's own post-codec
    # serving_uplink_bytes_total counter, not a model. With every axis
    # free the aware controller spends part of the byte win on routing
    # (compression makes offloading cheap, so the latency-optimal split
    # moves EARLIER -- bigger payloads, more offloads, much better p99),
    # so the >=4x byte claim is asserted on a split-pinned pair
    # (`branches` pins the deployed branch, p_tar held: the codec level
    # is the only knob) while the free-axes pair carries the p99 and
    # reliability-gap claims. All four assertions are CI gates.
    from repro.obs import MetricsRegistry, Observability
    from repro.serving.controller import ControllerConfig

    def _comp_arm(levels, pin_branch=False):
        cfg = ControllerConfig(
            interval_s=0.5, window_s=1.0, min_accuracy=0.9,
            compression_levels=levels,
            branches=(plan.exit_index + 1,) if pin_branch else None,
        )
        reg = MetricsRegistry()
        s, _ = scenario(True, obs=Observability(metrics=reg),
                        controller_config=cfg)
        return s, reg.counter_total("serving_uplink_bytes_total")

    blind, blind_bytes = _comp_arm(None)
    lvl0, lvl0_bytes = _comp_arm((0,))
    aware, aware_bytes = _comp_arm((0, 1, 2))
    pin_blind, pin_blind_bytes = _comp_arm(None, pin_branch=True)
    pin_aware, pin_aware_bytes = _comp_arm((0, 1, 2), pin_branch=True)
    byte_cut = pin_blind_bytes / max(pin_aware_bytes, 1.0)
    added_gap = aware["miscalibration_gap"] - blind["miscalibration_gap"]
    compression = {
        "levels": [0, 1, 2],
        "bytes_blind": blind,
        "level0_identity": lvl0,
        "compression_aware": aware,
        "uplink_bytes_blind": blind_bytes,
        "uplink_bytes_level0": lvl0_bytes,
        "uplink_bytes_aware": aware_bytes,
        "uplink_byte_cut_free_axes": blind_bytes / max(aware_bytes, 1.0),
        "pinned_split": {
            "branch": plan.exit_index + 1,
            "bytes_blind": pin_blind,
            "compression_aware": pin_aware,
            "uplink_bytes_blind": pin_blind_bytes,
            "uplink_bytes_aware": pin_aware_bytes,
            "uplink_byte_cut": byte_cut,
        },
        "added_reliability_gap": added_gap,
        "p99_blind_ms": blind["p99_ms"],
        "p99_aware_ms": aware["p99_ms"],
        "level0_bit_exact": lvl0 == blind and lvl0_bytes == blind_bytes,
    }
    if not compression["level0_bit_exact"]:
        raise AssertionError(
            "identity-codec (level 0) controller is not bit-exact with "
            "the bytes-blind controller")
    if byte_cut < 4.0:
        raise AssertionError(
            f"compression-aware controller cut uplink bytes only "
            f"{byte_cut:.2f}x (< 4x) at the pinned split")
    if added_gap > 0.01:
        raise AssertionError(
            f"compression added {added_gap:.4f} reliability gap (> 0.01)")
    if not aware["p99_ms"] < blind["p99_ms"]:
        raise AssertionError(
            f"compression-aware p99 {aware['p99_ms']:.1f}ms did not "
            f"strictly beat bytes-blind {blind['p99_ms']:.1f}ms")

    # metadata derived from the scenario module itself, never duplicated
    import inspect

    from repro.serving.scenarios import congested_markov_network

    sig = inspect.signature(run_congested_markov).parameters
    net = congested_markov_network()
    payload = {
        "scenario": {
            "arrival_rate_hz": sig["arrival_rate_hz"].default,
            "n_requests": n_requests,
            "network": (
                f"markov(good={net.good_bps / 1e6:g}Mbps,"
                f"bad={net.bad_bps / 1e6:g}Mbps)"
            ),
            "deadline_ms": sig["deadline_s"].default * 1e3,
            "profile": "paper_2020",
        },
        "static": static,
        "controller": ctrl,
        "obs_overhead": obs_overhead,
        "compression": compression,
        "p99_improvement": 1.0 - ctrl["p99_ms"] / static["p99_ms"],
        "miss_rate_improvement": static["deadline_miss_rate"]
        - ctrl["deadline_miss_rate"],
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    us = (wall_s + wall_c) / (2 * n_requests) * 1e6
    return us, (
        f"sim_rps={2 * n_requests / (wall_s + wall_c):.0f};"
        f"p99_static_ms={static['p99_ms']:.1f};"
        f"p99_ctrl_ms={ctrl['p99_ms']:.1f};"
        f"obs_overhead={obs_overhead['ratio']:.2f}x;"
        f"comp_bytes_cut={byte_cut:.1f}x;"
        f"comp_p99_ms={aware['p99_ms']:.1f};"
        f"artifact={out_path}"
    )


def bench_distortion_serving(n_requests=1500, out_path="BENCH_distortion.json"):
    """Offloading under drifting input distortion: uncalibrated plan vs the
    single global calibrated plan (fit on clean validation data, the
    paper's procedure) vs the expert PlanBank (one plan per distortion
    context + the cheap edge-side estimator picking the expert per
    sample). The scenario is repro.serving.scenarios.run_distortion_drift
    -- the SAME one tests/test_distortion.py pins down -- under a Markov
    severity schedule that visits all four regimes. Headline metric:
    on-device-weighted miscalibration gap |on-device accuracy - p_tar|
    per regime; CI asserts the bank beats the global plan. A second pair
    of arms serves the global plan WITH the online controller: once
    re-scoring on clean validation logits only (the original rule) and
    once context-AWARE (candidate tables weighted by the traffic mix the
    runtime's own telemetry observed; the fleet's rule ported back) --
    CI asserts the context-aware arm's gap is strictly smaller. Writes
    the fully deterministic BENCH_distortion.json."""
    from repro.serving.scenarios import (
        drift_contexts,
        drift_controller_config,
        fit_drift_plans,
        run_distortion_drift,
        severity_drift_schedule,
        synthetic_distorted_cascade,
    )

    val, test = synthetic_distorted_cascade()
    uncal, global_plan, bank = fit_drift_plans(val)
    sched = severity_drift_schedule()
    results, wall = {}, 0.0
    for name, plan in (
        ("uncalibrated", uncal),
        ("global_calibrated", global_plan),
        ("expert_bank", bank),
    ):
        t0 = time.perf_counter()
        tel = run_distortion_drift(plan, test, schedule=sched,
                                   n_requests=n_requests)
        wall += time.perf_counter() - t0
        results[name] = {
            "summary": tel.summary(),
            "per_context": tel.per_context_summary(),
        }
    g = results["global_calibrated"]["summary"]["miscalibration_gap"]
    b = results["expert_bank"]["summary"]["miscalibration_gap"]

    # controller arms (satellite of ISSUE 5): same global plan, same
    # reference controller config -- the only difference is the
    # INFORMATION the re-score prices (clean val logits vs the observed
    # traffic mix over all contexts' val logits)
    ctrl_results = {}
    for name, ca in (
        ("controller_clean_val", False),
        ("controller_context_aware", True),
    ):
        t0 = time.perf_counter()
        tel = run_distortion_drift(
            global_plan, test, schedule=severity_drift_schedule(),
            n_requests=n_requests, with_controller=True, val=val,
            context_aware=ca, controller_config=drift_controller_config(),
        )
        wall += time.perf_counter() - t0
        ctrl_results[name] = {
            "summary": tel.summary(),
            "per_context": tel.per_context_summary(),
        }
    gc = ctrl_results["controller_clean_val"]["summary"]["miscalibration_gap"]
    gx = ctrl_results["controller_context_aware"]["summary"]["miscalibration_gap"]

    # dwell-time vs controller-interval sweep (ROADMAP "bench breadth"):
    # how does the bank + online controller fare when regime drift is
    # faster or slower than the controller's re-score cadence? Each combo
    # serves the same workload under a fresh Markov schedule with the
    # given dwell; reported per combo: gap, p99, controller switches.
    sweep = []
    total_requests = 5 * n_requests  # three headline runs + two controller arms
    for dwell_s in (1.0, 3.0, 8.0):
        for interval_s in (0.5, 2.0):
            t0 = time.perf_counter()
            tel = run_distortion_drift(
                bank, test,
                schedule=severity_drift_schedule(dwell_s=dwell_s),
                n_requests=600, with_controller=True, val=val,
                controller_interval_s=interval_s,
            )
            wall += time.perf_counter() - t0
            total_requests += 600
            s = tel.summary()
            sweep.append({
                "dwell_s": dwell_s,
                "controller_interval_s": interval_s,
                "miscalibration_gap": s["miscalibration_gap"],
                "p99_ms": s["p99_ms"],
                "accuracy": s["accuracy"],
                "controller_switches": s["controller_switches"],
            })

    payload = {
        "scenario": {
            "contexts": [spec.key for spec in drift_contexts()],
            "schedule": f"markov(dwell={sched.dwell_s:g}s)",
            "n_requests": n_requests,
            "p_tar": bank.default_plan.p_tar,
            "profile": "paper_2020",
        },
        "plans": results,
        "controller_arms": ctrl_results,
        "gap_global": g,
        "gap_bank": b,
        "gap_improvement": g - b,
        "gap_controller_clean": gc,
        "gap_controller_context_aware": gx,
        "gap_context_aware_improvement": gc - gx,
        "dwell_interval_sweep": sweep,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    us = wall / total_requests * 1e6
    return us, (
        f"gap_uncal={results['uncalibrated']['summary']['miscalibration_gap']:.3f};"
        f"gap_global={g:.3f};gap_bank={b:.3f};"
        f"gap_ctrl_clean={gc:.3f};gap_ctrl_ctx={gx:.3f};artifact={out_path}"
    )


def bench_fleet(out_path="BENCH_fleet.json", scenario_names=None):
    """Fleet-scale vectorized serving: >=100k requests across >=64 cells
    (heterogeneous links, per-cell Markov severity drift, one shared
    cloud), simulated in seconds by `repro.fleet`. Compares the static
    UNCALIBRATED plan against the expert PlanBank driven by the
    context-aware fleet controller -- the scenario is
    repro.fleet.scenarios.reference_fleet, the SAME one
    tests/test_fleet.py pins down -- then sweeps the ADVERSARIAL
    orchestration matrix (`repro.orchestration.scenarios`: weather
    fronts, flash crowds, link outages, cloud brownouts, poisoned and
    good canary rollouts), each with its controller-vs-static (or
    rollout-vs-no-rollout) verdict. `scenario_names` filters the matrix
    (None = all registered; [] = skip). All simulated metrics are
    deterministic; the wall-clock throughput column is the speed claim
    the event-driven runtime cannot make. The ``fleet_compiled`` section
    records the fully compiled window pipeline (ISSUE 8): parity verdict
    vs host numpy plus honest CPU wall clocks at reference (64-cell) and
    scale (>=1M requests / >=256 cells) sizes."""
    from repro.fleet.scenarios import reference_fleet, run_fleet
    from repro.serving.scenarios import (
        fit_drift_plans,
        synthetic_distorted_cascade,
    )

    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"}
    )
    uncal, global_plan, bank = fit_drift_plans(val)
    scenario = reference_fleet(val=val, test=test)

    runs, wall = {}, {}
    for name, plan, ctrl in (
        ("static_uncalibrated", uncal, False),
        ("expert_bank_static", bank, False),
        ("expert_bank_controller", bank, True),
    ):
        t0 = time.perf_counter()
        tel = run_fleet(plan, scenario, with_controller=ctrl)
        wall[name] = time.perf_counter() - t0
        runs[name] = {
            "fleet": tel.fleet_summary(),
            "per_context": tel.per_context_summary(),
        }
    u = runs["static_uncalibrated"]["fleet"]
    c = runs["expert_bank_controller"]["fleet"]
    n_req = scenario.topology.n_requests
    total_wall = sum(wall.values())

    # gate-backend microbench (satellite of ISSUE 5): the same reference
    # gate table window-gated through the host numpy backend and the
    # jitted JAX backend, at the reference fleet's window sizes (one
    # 0.5 s window of the 64-cell fleet is ~640 arrivals) and the larger
    # windows a scaled-up fleet would push. Parity is asserted (identical
    # decisions, confidences to 1e-6); the speedup column is the
    # throughput claim and is machine-dependent.
    from repro.core.gatepath import GateTable

    tables = {
        name: GateTable(
            scenario.test["exit_logits"], scenario.test["final"], bank,
            labels=scenario.test["labels"],
            features_by_context=scenario.test["features"], backend=name,
        )
        for name in ("numpy", "jax")
    }
    rng = np.random.default_rng(0)
    n_cells = scenario.topology.n_cells
    gate_rows, parity = [], True
    for n_window in (640, 8192, 65536):
        ctx = rng.integers(0, len(tables["numpy"].ctx_keys), n_window)
        smp = rng.integers(0, tables["numpy"].n_samples, n_window)
        cells = rng.integers(0, n_cells, n_window)
        branch_by_cell = 1 + (np.arange(n_cells) % 2)
        p_tar_by_cell = np.where(np.arange(n_cells) % 3 == 0, 0.5, 0.8)
        out, us = {}, {}
        for name, table in tables.items():
            call = lambda: table.gate_window_cells(  # noqa: E731
                ctx, smp, cells, branch_by_cell, p_tar_by_cell, n_cells
            )
            call()  # warm the jit/trace cache outside the timing
            t0 = time.perf_counter()
            iters = 20
            for _ in range(iters):
                out[name] = call()
            us[name] = (time.perf_counter() - t0) / iters * 1e6
        ok = bool(
            np.array_equal(out["numpy"]["on_device"], out["jax"]["on_device"])
            and np.array_equal(out["numpy"]["prediction"], out["jax"]["prediction"])
            and np.allclose(out["numpy"]["confidence"], out["jax"]["confidence"],
                            rtol=1e-5, atol=1e-6)
        )
        parity = parity and ok
        gate_rows.append({
            "window": n_window,
            "numpy_us": us["numpy"],
            "jax_us": us["jax"],
            "speedup_jax_vs_numpy": us["numpy"] / us["jax"],
            "parity": ok,
        })
    # compiled fleet pipeline (ISSUE 8): the WHOLE window pipeline (gate
    # -> device FIFO queues -> uplink -> shared cloud) as ONE jitted
    # program, max-plus associative_scan recurrences, shard_map over the
    # cell axis. Two sub-runs, both parity-checked against host numpy:
    # the 64-cell reference (same scenario as above) and a >=1M-request
    # / >=256-cell scale run -- the CI-runner floor; 10M+ requests
    # across 1000+ cells is the accelerator target the same program
    # reaches by sharding cells over real devices. Wall clocks are
    # honest CPU numbers: at reference scale the fixed compile/dispatch
    # cost still loses to numpy, at 1M+ the compiled path wins big.
    def _timed_run(plan, scn, backend=None):
        t0 = time.perf_counter()
        tel = run_fleet(plan, scn, backend=backend)
        return tel.fleet_summary(), time.perf_counter() - t0

    def _summaries_match(a, b):
        return bool(all(
            np.allclose(b[k], a[k], rtol=1e-9, atol=1e-12) for k in a
        ))

    ref_np = runs["expert_bank_static"]["fleet"]  # numpy arm, timed above
    ref_np_s = wall["expert_bank_static"]
    _, ref_c_cold_s = _timed_run(bank, scenario, backend="compiled")
    ref_c, ref_c_s = _timed_run(bank, scenario, backend="compiled")
    scale_scn = reference_fleet(n_cells=256, requests_per_cell=4096,
                                val=val, test=test)
    scale_np, scale_np_s = _timed_run(bank, scale_scn)
    scale_c, scale_c_s = _timed_run(bank, scale_scn, backend="compiled")
    n_scale = scale_scn.topology.n_requests
    compiled_parity = (_summaries_match(ref_np, ref_c)
                       and _summaries_match(scale_np, scale_c))
    fleet_compiled = {
        "parity": compiled_parity,
        "requests": n_scale,
        "cells": scale_scn.topology.n_cells,
        "devices": jax.device_count(),
        "mesh": "auto: 1-D shard_map mesh over local devices, axis "
                "'cells' (single-device on the CI runner)",
        "accelerator_target": {
            "requests": 10_000_000, "cells": 1000,
            "note": "same jitted program, cells sharded over real "
                    "devices; CI runner numbers below are CPU-bound",
        },
        "reference": {
            "requests": n_req,
            "cells": scenario.topology.n_cells,
            "numpy_s": ref_np_s,
            "compiled_cold_s": ref_c_cold_s,
            "compiled_warm_s": ref_c_s,
            "speedup_compiled_vs_numpy": ref_np_s / ref_c_s,
        },
        "scale": {
            "requests": n_scale,
            "cells": scale_scn.topology.n_cells,
            "numpy_s": scale_np_s,
            "compiled_s": scale_c_s,
            "numpy_rps": n_scale / scale_np_s,
            "compiled_rps": n_scale / scale_c_s,
            "speedup_compiled_vs_numpy": scale_np_s / scale_c_s,
        },
    }

    # fleet compression sweep (ISSUE 10): the same three-arm codec sweep
    # as BENCH_serving, on the 64-cell fleet. Bytes-blind re-uses the
    # reference controller config; level-0-only restricts the axis to
    # the identity codec and MUST reproduce the bytes-blind run (and the
    # obs-off expert_bank_controller arm above) bit-exactly; the
    # compression-aware arm prices levels 0/1/2 per (cell, candidate).
    # Uplink bytes come from the simulator's own per-cell
    # fleet_uplink_bytes_total counter (uplink + backhaul, post-codec).
    # The compiled stack's level-0 identity is the `fleet_compiled`
    # parity verdict above (static deployments run at level 0); a
    # level-2 static plan is additionally parity-checked host-vs-
    # compiled so the codec axis itself is pinned across backends.
    from repro.fleet.controller import FleetControllerConfig
    from repro.obs import MetricsRegistry, Observability

    def _comp_fleet_arm(levels, pin_branch=False):
        cfg = FleetControllerConfig(
            interval_s=1.0, window_s=2.0,
            p_tar_grid=None if pin_branch else (0.3, 0.5, 0.7, 0.8),
            branches=((bank.default_plan.exit_index + 1,)
                      if pin_branch else None),
            min_accuracy=0.8, cloud_rho_max=0.9,
            compression_levels=levels,
        )
        reg = MetricsRegistry()
        tel = run_fleet(bank, scenario, with_controller=True,
                        controller_config=cfg,
                        obs=Observability(metrics=reg))
        return (tel.fleet_summary(),
                reg.counter_total("fleet_uplink_bytes_total"))

    blind_f, blind_f_bytes = _comp_fleet_arm(None)
    lvl0_f, lvl0_f_bytes = _comp_fleet_arm((0,))
    aware_f, aware_f_bytes = _comp_fleet_arm((0, 1, 2))
    pin_blind_f, pin_blind_f_bytes = _comp_fleet_arm(None, pin_branch=True)
    pin_aware_f, pin_aware_f_bytes = _comp_fleet_arm((0, 1, 2),
                                                     pin_branch=True)
    plan_l2 = global_plan.with_compression(2)
    l2_np, _ = _timed_run(plan_l2, scenario)
    l2_c, _ = _timed_run(plan_l2, scenario, backend="compiled")
    byte_cut_f = pin_blind_f_bytes / max(pin_aware_f_bytes, 1.0)
    added_gap_f = (aware_f["miscalibration_gap"]
                   - blind_f["miscalibration_gap"])
    compression = {
        "levels": [0, 1, 2],
        "bytes_blind": blind_f,
        "level0_identity": lvl0_f,
        "compression_aware": aware_f,
        "uplink_bytes_blind": blind_f_bytes,
        "uplink_bytes_level0": lvl0_f_bytes,
        "uplink_bytes_aware": aware_f_bytes,
        "uplink_byte_cut_free_axes": blind_f_bytes / max(aware_f_bytes, 1.0),
        "pinned_split": {
            "branch": bank.default_plan.exit_index + 1,
            "bytes_blind": pin_blind_f,
            "compression_aware": pin_aware_f,
            "uplink_bytes_blind": pin_blind_f_bytes,
            "uplink_bytes_aware": pin_aware_f_bytes,
            "uplink_byte_cut": byte_cut_f,
        },
        "added_reliability_gap": added_gap_f,
        "p99_blind_ms": blind_f["p99_ms"],
        "p99_aware_ms": aware_f["p99_ms"],
        "level0_bit_exact": (lvl0_f == blind_f
                             and lvl0_f_bytes == blind_f_bytes
                             and lvl0_f == c),
        "compiled_level2_parity": _summaries_match(l2_np, l2_c),
    }
    if not compression["level0_bit_exact"]:
        raise AssertionError(
            "fleet identity-codec (level 0) controller is not bit-exact "
            "with the bytes-blind controller")
    if byte_cut_f < 4.0:
        raise AssertionError(
            f"fleet compression-aware controller cut uplink bytes only "
            f"{byte_cut_f:.2f}x (< 4x) at the pinned split")
    if added_gap_f > 0.01:
        raise AssertionError(
            f"fleet compression added {added_gap_f:.4f} reliability gap "
            f"(> 0.01)")
    if not aware_f["p99_ms"] < blind_f["p99_ms"]:
        raise AssertionError(
            f"fleet compression-aware p99 {aware_f['p99_ms']:.1f}ms did "
            f"not strictly beat bytes-blind {blind_f['p99_ms']:.1f}ms")
    if not compression["compiled_level2_parity"]:
        raise AssertionError(
            "compiled backend diverged from host numpy on the level-2 "
            "static plan")

    # adversarial orchestration matrix (churn, QoS, canary rollouts)
    from repro.orchestration import run_scenarios

    t0 = time.perf_counter()
    adversarial = run_scenarios(names=scenario_names)
    adversarial_wall = time.perf_counter() - t0

    payload = {
        "scenario": {
            "cells": scenario.topology.n_cells,
            "requests": n_req,
            "requests_per_cell": n_req // scenario.topology.n_cells,
            "cloud_servers": scenario.topology.cloud_servers,
            "contexts": scenario.contexts,
            "directions": {"gaussian_blur": "under"},
            "profile": "paper_2020",
        },
        "plans": runs,
        "p99_uncal_ms": u["p99_ms"],
        "p99_controller_ms": c["p99_ms"],
        "p99_improvement": 1.0 - c["p99_ms"] / u["p99_ms"],
        "gap_uncal": u["miscalibration_gap"],
        "gap_controller": c["miscalibration_gap"],
        "gap_improvement": u["miscalibration_gap"] - c["miscalibration_gap"],
        "gate_backend": {"parity": parity, "windows": gate_rows},
        "fleet_compiled": fleet_compiled,
        "compression": compression,
        "adversarial_scenarios": adversarial,
        "adversarial_wall_s": adversarial_wall,
        # wall-clock figures are machine-dependent and excluded from any
        # determinism assertion; they are the throughput claim
        "wall_clock": {
            "seconds_per_run": wall,
            "requests_per_second": {k: n_req / v for k, v in wall.items()},
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    us = total_wall / (len(runs) * n_req) * 1e6
    n_pass = sum(1 for r in adversarial if r["pass"])
    fc = fleet_compiled["scale"]
    return us, (
        f"cells={scenario.topology.n_cells};requests={n_req};"
        f"sim_rps={len(runs) * n_req / total_wall:.0f};"
        f"p99_uncal={u['p99_ms']:.0f}ms;p99_ctrl={c['p99_ms']:.0f}ms;"
        f"gap_uncal={u['miscalibration_gap']:.3f};"
        f"gap_ctrl={c['miscalibration_gap']:.3f};"
        f"compiled_parity={compiled_parity};"
        f"comp_bytes_cut={byte_cut_f:.1f}x;"
        f"compiled_1M_rps={fc['compiled_rps']:.0f}"
        f"(numpy={fc['numpy_rps']:.0f});"
        f"scenarios={n_pass}/{len(adversarial)};artifact={out_path}"
    )


def bench_emit_obs(out_prefix="OBS"):
    """Re-run the reference serving and fleet scenarios with the full
    observability plane (`repro.obs`) attached and write the artifacts
    next to the BENCH files:

      {prefix}_serving_trace.jsonl   unsampled per-request trace
      {prefix}_serving_metrics.json  metrics registry (JSON export)
      {prefix}_serving_metrics.prom  same registry, Prometheus text
      {prefix}_serving_audit.jsonl   online-controller decision audit
      {prefix}_serving_calibration.json  reliability sketch of the run
      {prefix}_fleet_trace.jsonl     sampled trace of the >=100k fleet run
      {prefix}_fleet_metrics.json/.prom
      {prefix}_fleet_calibration.json
      {prefix}_fleet_audit.jsonl     guarded poisoned-canary rollout audit
                                     (holds the full trip->rollback chain,
                                     tripped by the CALIBRATION SLO)
      {prefix}_drift_calibration.json  sketch of a poisoned deployment
      {prefix}_bank.json             the poisoned candidate bank (its
                                     metadata still carries the honest
                                     fit-time val ECE, which is exactly
                                     what the drift report diffs against)

    Every artifact is cross-examined in-process with `repro.obs.check`
    before returning (CI re-runs the CLI against the files); a violated
    invariant fails the bench. The canary arm additionally asserts the
    EARLY-WARNING claim: the under-confident poison offloads its
    traffic, so the reliability-gap SLO (on-device label outcomes only)
    never reaches its evidence floor -- the windowed calibration gauges
    are the only stream that trips, and they must trip before any
    gap-family verdict."""
    from repro.core.calibration import TemperatureScaling
    from repro.core.policy import OffloadPlan
    from repro.fleet.scenarios import reference_fleet, run_fleet
    from repro.obs import (
        AuditLog,
        JsonlTraceSink,
        MetricsRegistry,
        Observability,
        ReliabilitySketch,
    )
    from repro.obs.check import (
        check_calibration,
        run_checks,
        verify_rollback_chain,
    )
    from repro.obs.trace import read_jsonl
    from repro.serving.scenarios import (
        fit_drift_plans,
        run_congested_markov,
        synthetic_cascade_logits,
        synthetic_distorted_cascade,
    )

    t_start = time.perf_counter()

    # serving: the BENCH_serving controller arm, traced unsampled
    exits, final, y = synthetic_cascade_logits(2048)
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0),
                     TemperatureScaling.from_temperature(1.0)],
    )
    audit_s, metrics_s = AuditLog(), MetricsRegistry()
    obs_s = Observability(
        trace=JsonlTraceSink(f"{out_prefix}_serving_trace.jsonl"),
        audit=audit_s, metrics=metrics_s, calibration=ReliabilitySketch(),
    )
    run_congested_markov(plan, exits, final, y, n_requests=2000,
                         with_controller=True, obs=obs_s)
    obs_s.close()
    metrics_s.write_json(f"{out_prefix}_serving_metrics.json")
    metrics_s.write_prometheus(f"{out_prefix}_serving_metrics.prom")
    audit_s.to_jsonl(f"{out_prefix}_serving_audit.jsonl")
    obs_s.calibration.save(f"{out_prefix}_serving_calibration.json")
    errors = run_checks(
        read_jsonl(f"{out_prefix}_serving_trace.jsonl"),
        metrics_s, audit_s.records, calibration=obs_s.calibration,
    )

    # fleet: the full reference fleet (>=100k requests), sampled trace
    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"}
    )
    _, _, bank = fit_drift_plans(val)
    scn = reference_fleet(val=val, test=test)
    sample_every = 101
    metrics_f = MetricsRegistry()
    obs_f = Observability(
        trace=JsonlTraceSink(f"{out_prefix}_fleet_trace.jsonl"),
        metrics=metrics_f, trace_sample_every=sample_every,
        calibration=ReliabilitySketch(),
    )
    run_fleet(bank, scn, with_controller=True, obs=obs_f)
    obs_f.close()
    metrics_f.write_json(f"{out_prefix}_fleet_metrics.json")
    metrics_f.write_prometheus(f"{out_prefix}_fleet_metrics.prom")
    obs_f.calibration.save(f"{out_prefix}_fleet_calibration.json")
    errors += run_checks(
        read_jsonl(f"{out_prefix}_fleet_trace.jsonl"), metrics_f,
        calibration=obs_f.calibration,
    )

    # fleet audit: a guarded poisoned-canary rollout whose SLO watches
    # the streaming calibration gauges, so the artifact CI cross-examines
    # holds a complete CALIBRATION trip -> rollback causal chain. The
    # poison is UNDER-confidence (T x20): the canary offloads nearly
    # everything, the gap-family SLOs starve below their gate-sample
    # evidence floor, and only the calibration stream (which covers
    # offloaded requests too) can see the failure.
    from repro.orchestration.qos import CellSLO
    from repro.orchestration.scenarios import _rollout_pieces, poisoned_bank

    scn_small = reference_fleet(n_cells=8, requests_per_cell=300,
                                cloud_servers=2, val=val, test=test)
    # ece_cap sits between the incumbent's windowed per-cell ECE (~0.21
    # on these small windows) and the poisoned canary's (~0.45): the
    # incumbent never trips, the canary always does.
    cal_slo = CellSLO(reliability_shortfall=0.12, ece_cap=0.30,
                      min_requests=12, min_gate_samples=25)
    orch, monitor, _ = _rollout_pieces(
        scn_small, poisoned_bank(bank, temp_scale=20.0), slo=cal_slo)
    audit_f, metrics_a = AuditLog(), MetricsRegistry()
    cal_a = ReliabilitySketch()
    run_fleet(bank, scn_small, orchestrator=orch,
              obs=Observability(audit=audit_f, metrics=metrics_a,
                                calibration=cal_a))
    audit_f.to_jsonl(f"{out_prefix}_fleet_audit.jsonl")
    cal_a.save(f"{out_prefix}_fleet_audit_calibration.json")
    errors += check_calibration(cal_a, metrics=metrics_a)
    chain = verify_rollback_chain(audit_f.records)
    if not chain["ok"]:
        errors.append(f"rollback chain broken: {chain['why']}")
    trips = audit_f.filter(actor="qos_monitor", action="qos_trip")
    ece_trips = [r for r in trips if r["evidence"]["metric"] == "ece"]
    gap_trips = [r for r in trips if r["evidence"]["metric"]
                 in ("reliability_gap", "reliability_shortfall")]
    if not ece_trips:
        errors.append("calibration SLO never tripped on the poisoned canary")
    elif gap_trips and min(r["t_s"] for r in gap_trips) <= min(
            r["t_s"] for r in ece_trips):
        errors.append("gap-family SLO tripped before the calibration SLO")

    # drift-report artifacts: a poisoned bank deployed STATICALLY, plus
    # the bank file itself (whose metadata still carries the honest
    # fit-time val ECE) -- `repro.obs.calibration_report` must flag it
    from repro.obs.calibration_report import build_report

    bad = poisoned_bank(bank)
    cal_d = ReliabilitySketch()
    run_fleet(bad, scn_small, obs=Observability(calibration=cal_d))
    cal_d.save(f"{out_prefix}_drift_calibration.json")
    bad.save(f"{out_prefix}_bank.json")
    report = build_report(
        cal_d,
        bank_meta={**bad.metadata, "default_context": bad.default_context},
    )
    if not report["flagged"]:
        errors.append("drift report did not flag the poisoned deployment")
    if errors:
        raise AssertionError(
            "obs invariants violated: " + "; ".join(errors[:5])
        )

    n_total = 2000 + scn.topology.n_requests
    us = (time.perf_counter() - t_start) / n_total * 1e6
    return us, (
        f"fleet_requests={scn.topology.n_requests};"
        f"trace_sample_every={sample_every};"
        f"audit_records={len(audit_f)};rollback_chain=ok;"
        f"artifacts={out_prefix}_*"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip figure benchmarks")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument(
        "--scenario",
        default=None,
        help="comma-separated adversarial scenario names for the fleet "
        "bench (default: all registered; 'none' skips the matrix)",
    )
    ap.add_argument(
        "--emit-obs",
        action="store_true",
        help="re-run the reference scenarios with the observability plane "
        "attached and write OBS_* trace/metrics/audit artifacts next to "
        "the BENCH files",
    )
    args, _ = ap.parse_known_args()
    if args.scenario is None or args.scenario == "all":
        scenario_names = None
    elif args.scenario == "none":
        scenario_names = []
    else:
        scenario_names = [s for s in args.scenario.split(",") if s]

    print("name,us_per_call,derived")
    rows = [
        ("exit_gate_jnp", *bench_exit_gate_jnp()),
        ("exit_gate_kernel_interpret", *bench_exit_gate_kernel()),
        ("plan_gate_fastpath", *bench_plan_gate()),
        ("calibration_fit_temperature", *bench_calibration_fit()),
        ("b_alexnet_train_step", *bench_b_alexnet_step()),
        ("smoke_decode_step", *bench_smoke_decode()),
        ("serving_runtime_per_request", *bench_serving_runtime()),
        ("distortion_drift_per_request", *bench_distortion_serving()),
        ("fleet_simulator_per_request",
         *bench_fleet(scenario_names=scenario_names)),
    ]
    if args.emit_obs:
        rows.append(("observability_emit", *bench_emit_obs()))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if not args.quick:
        t0 = time.perf_counter()
        from benchmarks.paper_figures import run_all

        res = run_all(epochs=args.epochs)
        us = (time.perf_counter() - t0) * 1e6
        t = res["temps"]
        # headline claim numbers (Fig. 4 at the outage knee): conv vs cal
        f4 = {r[0]: (r[1], r[2]) for r in res["fig4"]}
        knee = next((p for p in sorted(f4) if f4[p][0] > 0), max(f4))
        convk, calk = f4[knee]
        print(
            f"paper_figures_all,{us:.1f},"
            f"T1={t[0]:.2f};outage@{knee} conv={convk:.3f} cal={calk:.3f}"
        )

        # roofline summary (from cached dry-run artifacts if present)
        try:
            from benchmarks.roofline import table

            rl = table(mesh="16x16")
            n_dom = {}
            for r in rl:
                n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
            print(f"roofline_pairs,{len(rl)},dominant_counts={n_dom}")
        except Exception as e:  # dry-run artifacts absent
            print(f"roofline_pairs,0,unavailable:{e}")


if __name__ == "__main__":
    main()
