"""Re-derive roofline cost fields from archived HLO dumps without
recompiling: reads experiments/hlo/<stem>.hlo.zst, re-runs the cost model
(repro.launch.hlo_cost), and updates the matching dry-run JSON in place.

  PYTHONPATH=src:. python -m benchmarks.recost
"""
from __future__ import annotations

import glob
import json
import os

import zstandard

from repro.launch.hlo_cost import analyze_text


def main():
    n = 0
    for zf in sorted(glob.glob("experiments/hlo/*.hlo.zst")):
        stem = os.path.basename(zf)[: -len(".hlo.zst")]
        jf = os.path.join("experiments", "dryrun", stem + ".json")
        if not os.path.exists(jf):
            continue
        hlo = zstandard.ZstdDecompressor().decompress(
            open(zf, "rb").read(), max_output_size=1 << 31
        ).decode()
        cost = analyze_text(hlo)
        rec = json.load(open(jf))
        rec["flops"] = cost["flops"]
        rec["bytes_accessed"] = cost["bytes"]
        rec["collective_bytes"] = cost["collective_bytes"]
        rec["collective_counts"] = cost["collective_counts"]
        json.dump(rec, open(jf, "w"), indent=1)
        n += 1
        print("recosted", stem)
    print(f"{n} records updated")


if __name__ == "__main__":
    main()
