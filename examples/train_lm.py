"""End-to-end LM training driver (deliverable b): train an early-exit
language model for a few hundred steps on the synthetic token stream and
watch the exits learn (per-exit loss drops below the uniform floor), then
calibrate the exits and serve a few tokens through the early-exit gate.

Defaults to a tiny mamba2-family model for CPU; pass --preset 100m for the
~100M-parameter configuration used on real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core.calibration import fit_temperature
from repro.data.pipeline import TokenIterator
from repro.data.synthetic import lm_sequences
from repro.launch.serve import make_serve_step
from repro.models import registry
from repro.training import optim
from repro.training.loop import make_eval_step, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    if args.preset == "100m":
        cfg = get_config(args.arch)  # mamba2-130m is the ~100M-class config
    else:
        cfg = get_smoke(args.arch).replace(vocab_size=512)
    print(f"config {cfg.name}: {cfg.param_count():,} params, exits at "
          f"{cfg.exit_layers}")

    key = jax.random.PRNGKey(0)
    params = registry.init_params(key, cfg)
    opt_cfg = optim.AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=20)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
    state = optim.init(params)

    stream = lm_sequences(800_000, cfg.vocab_size, seed=0, order=1, branch=4)
    it = iter(TokenIterator(stream, args.batch, args.seq))
    floor = np.log(4)  # teacher branching factor
    print(f"uniform loss floor: log(V)={np.log(cfg.vocab_size):.2f}; "
          f"teacher entropy~{floor:.2f}")
    for i in range(args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, m = step(params, state, batch)
        if i % 25 == 0 or i == args.steps - 1:
            exits = " ".join(
                f"exit{j}={float(m[f'loss_exit{j}']):.3f}"
                for j in range(len(cfg.exit_layers))
            )
            print(f"step {i:4d} final={float(m['loss_final']):.3f} {exits}")

    # --- calibrate the exits on held-out tokens -----------------------------
    eval_step = make_eval_step(cfg)
    batch = next(it)
    out = eval_step(params, {k: jnp.asarray(v) for k, v in batch.items()})
    temps = []
    for j, ex in enumerate(out["exit_logits"]):
        z = ex.reshape(-1, cfg.vocab_size)
        y = jnp.asarray(batch["labels"]).reshape(-1)
        T, info = fit_temperature(z, y)
        temps.append(float(T))
        print(f"exit {j}: T={float(T):.3f} "
              f"(NLL {float(info['nll_before']):.3f}->{float(info['nll_after']):.3f})")

    # --- serve a few tokens through the calibrated early-exit gate ----------
    serve = jax.jit(make_serve_step(cfg, temperatures=temps))
    caches = registry.init_cache(cfg, 2, 64)
    tok = jnp.asarray(batch["tokens"][:2, :1])
    exited_early = 0
    for t in range(32):
        out, caches = serve(params, tok, caches, jnp.int32(t))
        conf = np.asarray(out["exit_confidence"])  # (n_exits, batch)
        exited_early += int((conf.max(0) > 0.8).sum())
        tok = out["token"][:, None]
    print(f"\nserved 32 tokens x 2 seqs; {exited_early}/64 token-steps cleared "
          f"the calibrated 0.8-confidence gate at an early exit.")


if __name__ == "__main__":
    main()
