"""Early-exit LM serving across the edge/cloud partition (deliverable b).

The LM analogue of the paper's Fig. 1: the *edge partition* runs blocks up
to the first exit and answers a classification-style query (next-token
prediction at prefill) when the calibrated gate clears p_tar; refused
requests ship the partition activation to the *cloud partition*.

Uses the OffloadEngine with the lm bindings, so the exact routing/batching
machinery that serves the convnet serves a transformer too.

Run:  PYTHONPATH=src python examples/serve_earlyexit.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import make_plan
from repro.data.pipeline import TokenIterator
from repro.data.synthetic import lm_sequences
from repro.models import registry, transformer
from repro.offload.engine import lm_engine
from repro.training import optim
from repro.training.loop import make_train_step


def main():
    cfg = get_smoke("qwen3-8b").replace(vocab_size=256)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)

    # brief training so exits are meaningful (1st-order Markov teacher,
    # branching factor 4 -- learnable in a few hundred steps)
    opt_cfg = optim.AdamWConfig(lr=2e-3, total_steps=240, warmup_steps=20)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
    state = optim.init(params)
    stream = lm_sequences(400_000, cfg.vocab_size, seed=0, order=1, branch=4)
    it = iter(TokenIterator(stream, 16, 64))
    for i in range(240):
        b = next(it)
        params, state, m = step(params, state, {k: jnp.asarray(v) for k, v in b.items()})
    print(f"trained 240 steps: final loss {float(m['loss_final']):.3f}, "
          f"exit0 loss {float(m['loss_exit0']):.3f} (floor ~{1.386:.2f})")

    # validation pass -> calibrated policy for exit 0
    vb = next(it)
    out = transformer.edge_forward(
        params, cfg, {"tokens": jnp.asarray(vb["tokens"])}, exit_index=0
    )
    vlogits = out["exit_logits"][:, 0, :]
    vlabels = jnp.asarray(vb["labels"][:, -1])
    for calibrated in (False, True):
        # p_tar chosen inside the partially-trained model's confidence range
        plan = make_plan([vlogits], vlabels, p_tar=0.3, calibrated=calibrated)
        engine = lm_engine(params, cfg, plan)
        hits = 0
        total = 0
        for _ in range(8):
            b = next(it)
            res = engine.infer({"tokens": jnp.asarray(b["tokens"])})
            hits += int((res["prediction"] == b["labels"][:, -1]).sum())
            total += len(res["prediction"])
        tag = "calibrated " if calibrated else "conventional"
        print(
            f"{tag}: T={plan.temperatures[0]:.2f} "
            f"on-device={1-engine.stats.offload_rate:.2f} "
            f"next-token acc={hits/total:.3f} "
            f"payload shipped={engine.stats.payload_bytes/1e6:.2f} MB"
        )


if __name__ == "__main__":
    main()
