"""Edge-cloud offloading simulation: the paper's missed-deadline experiment
(Sec. IV-E) on the partitioned serving ENGINE, not just logits math.

Builds the two jitted partitions of B-AlexNet (edge = conv1 + branch1,
cloud = the rest), wraps them in the OffloadEngine with a conventional and
a calibrated OffloadPlan (deployed via its JSON serialization, as an edge
device would receive it), serves the test set in request batches, and reports
offload rate / accuracy / estimated latency / missed-deadline probability
under the paper's latency constants (i7 edge, K80 cloud, 18.8 Mbps uplink).

Run:  PYTHONPATH=src python examples/offload_simulation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OffloadPlan, make_plan
from repro.data.synthetic import cifar_like
from repro.models import convnet
from repro.models.convnet import B_ALEXNET
from repro.offload import latency as L
from repro.offload.engine import convnet_engine
from repro.training import optim
from repro.training.loop import make_train_step


def train(data, steps_per_epoch=60, epochs=4):
    params = convnet.init_params(jax.random.PRNGKey(0))
    opt = optim.AdamWConfig(lr=2e-3, total_steps=epochs * steps_per_epoch)
    step = jax.jit(make_train_step(B_ALEXNET, opt, remat=False))
    state = optim.init(params)
    rng = np.random.default_rng(0)
    for _ in range(epochs):
        order = rng.permutation(len(data.train_y))
        for s in range(0, steps_per_epoch * 128, 128):
            idx = order[s : s + 128]
            b = {
                "images": jnp.asarray(data.train_x[idx]),
                "labels": jnp.asarray(data.train_y[idx]),
            }
            params, state, _ = step(params, state, b)
    return params


def main():
    data = cifar_like(n_train=10_000, n_val=2_000, n_test=4_096, seed=1)
    params = train(data)

    # validation logits for policy construction
    @jax.jit
    def edge_logits(x):
        l, _ = convnet.edge_forward(params, x, branch=1)
        return l

    vlog = np.concatenate(
        [
            np.asarray(edge_logits(jnp.asarray(data.val_x[s : s + 512])))
            for s in range(0, len(data.val_x), 512)
        ]
    )

    profile = L.paper_2020()
    p_tar = 0.85
    print(f"latency constants: edge(conv1+branch)={L.edge_time(profile,1)*1e3:.3f} ms, "
          f"uplink={L.comm_time(profile,1)*1e3:.3f} ms, "
          f"cloud={L.cloud_time(profile,1)*1e3:.3f} ms per sample")

    for calibrated in (False, True):
        plan = make_plan([jnp.asarray(vlog)], jnp.asarray(data.val_y),
                         p_tar=p_tar, calibrated=calibrated)
        # deploy the serialized artifact, exactly as an edge device would
        plan = OffloadPlan.from_json(plan.to_json())
        engine = convnet_engine(params, plan, branch=1)
        correct = 0
        times = []
        for s in range(0, len(data.test_y), 512):
            batch = {"images": jnp.asarray(data.test_x[s : s + 512])}
            out = engine.infer(batch)
            correct += int((out["prediction"] == data.test_y[s : s + 512]).sum())
            on_dev = out["on_device"]
            t = np.where(
                on_dev,
                L.edge_time(profile, 1),
                L.edge_time(profile, 1) + L.comm_time(profile, 1) + L.cloud_time(profile, 1),
            )
            times.append(t.mean())
        acc = correct / len(data.test_y)
        name = "calibrated " if calibrated else "conventional"
        print(
            f"{name}: T={plan.temperatures[0]:.2f} "
            f"offload_rate={engine.stats.offload_rate:.2f} "
            f"accuracy={acc:.3f} mean_batch_latency={np.mean(times)*1e3:.3f} ms "
            f"payload={engine.stats.payload_bytes/1e6:.1f} MB total"
        )
    print("\nthe calibrated engine offloads more (it refuses unreliable exits)"
          "\nand recovers the accuracy target at a modest latency cost.")


if __name__ == "__main__":
    main()
