"""Serving under load: arrival rate x network regime, calibrated vs not.

The paper's experiment prices offloading at one fixed 18.8 Mbps uplink and
reports mean batch latency. This example runs the event-driven serving
runtime instead: N requests arrive as a Poisson stream at each rate, every
refused sample queues through a microbatcher, ONE shared uplink (fixed /
Markov good-bad Wi-Fi / bandwidth-trace replay), and the cloud tier --
reporting tail latency and deadline misses, which the static math cannot
express.

Two plans are compared on identical logits and identical randomness:
  * conventional -- identity calibration (T=1), the overconfident baseline;
  * calibrated   -- per-exit Temperature Scaling (the paper's method).
With --controller, the Edgent-style online controller re-scores the
calibrated plan's calibrators against measured bandwidth each second.

Run:  PYTHONPATH=src python examples/serve_under_load.py [--controller]
      [--requests 2000]

With --cells N the same comparison runs at FLEET scale through the
vectorized simulator (`repro.fleet`): N cells, each with its own device
pair, its own uplink drawn from the fixed/markov/trace mix, one shared
cloud -- hundreds of thousands of requests in seconds instead of one
event loop per request. --controller then deploys the fleet controller
(per-cell re-scoring, shared-cloud cap) for the calibrated plan.

      PYTHONPATH=src python examples/serve_under_load.py --cells 64
      [--controller] [--requests 2000]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.policy import make_plan
from repro.offload import latency as L
from repro.serving import (
    ControllerConfig,
    FixedRateNetwork,
    LogitsCore,
    MarkovNetwork,
    OnlineController,
    RuntimeConfig,
    ServingRuntime,
    TraceNetwork,
    poisson_workload,
)


def synthetic_exit_logits(n, c=10, seed=0, hard_frac=0.35, overconf=3.0):
    """A deterministic stand-in for a trained B-AlexNet's validation/test
    logits: a hard fraction of samples that shallow features cannot
    separate, and an overconfidence factor that mimics the miscalibration
    Temperature Scaling later removes (paper Fig. 2)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, c, n)
    hard = rng.random(n) < hard_frac
    z1 = rng.normal(size=(n, c)).astype(np.float32)
    z1[np.arange(n), y] += np.where(hard, 0.2, 2.5)
    z1 *= overconf  # shallow head: overconfident
    hard2 = hard & (rng.random(n) < 0.6)  # the deeper exit resolves some
    z2 = rng.normal(size=(n, c)).astype(np.float32)
    z2[np.arange(n), y] += np.where(hard2, 0.3, 3.0)
    z2 *= overconf
    final = rng.normal(size=(n, c)).astype(np.float32) * 0.3
    final[np.arange(n), y] += 4.0  # cloud main head: near-oracle
    return {1: z1, 2: z2}, final, y


def networks(profile):
    return {
        "fixed": lambda: FixedRateNetwork(profile.uplink_bps),
        "markov": lambda: MarkovNetwork(
            good_bps=profile.uplink_bps, bad_bps=2e6,
            p_good_to_bad=0.4, p_bad_to_good=0.2, dwell_s=1.0, seed=7,
        ),
        "trace": lambda: TraceNetwork(
            [0.0, 4.0, 6.0, 10.0],
            [profile.uplink_bps, 3e6, 8e6, profile.uplink_bps],
            period_s=14.0,
        ),
    }


def run_fleet_scale(args, profile, p_tar, plans, test_exits, test_final,
                    test_y, val_exits, val_final, val_y):
    """The --cells fast path: the same plans served over an N-cell fleet
    by the vectorized simulator instead of the per-request event loop."""
    import time

    from repro.fleet import (
        CellConfig,
        FleetConfig,
        FleetController,
        FleetControllerConfig,
        FleetGateTable,
        FleetSimulator,
        FleetTopology,
    )
    from repro.fleet.topology import poisson_cell_workload

    nets = networks(profile)
    net_names = list(nets)
    n_test = len(test_y)
    cells = [
        CellConfig(
            network=nets[net_names[i % len(net_names)]](),
            workload=poisson_cell_workload(
                60.0, args.requests, n_test, n_devices=2, seed=100 + i
            ),
            n_devices=2,
            deadline_s=0.1,
        )
        for i in range(args.cells)
    ]
    topology = FleetTopology(cells, cloud_servers=4)
    print(f"\n== fleet fast path: {args.cells} cells x {args.requests} "
          f"requests = {topology.n_requests} total ==")
    print(f"{'plan':12s} {'wall_s':>7s} {'sim_rps':>9s} {'p50ms':>8s} "
          f"{'p95ms':>8s} {'p99ms':>9s} {'miss%':>6s} {'offl%':>6s} "
          f"{'acc':>5s} {'sw':>4s}")
    for plan_name, plan in plans.items():
        table = FleetGateTable.from_logits(test_exits, test_final, plan,
                                           labels=test_y)
        controller = None
        if args.controller and plan_name == "calibrated":
            controller = FleetController(
                plan, profile, val_exits, n_cells=args.cells,
                final_logits=val_final, labels=val_y, cloud_servers=4,
                config=FleetControllerConfig(
                    interval_s=1.0, window_s=2.0,
                    p_tar_grid=(0.5, 0.7, p_tar), min_accuracy=0.9,
                ),
            )
        t0 = time.perf_counter()
        tel = FleetSimulator(
            table, topology, profile,
            config=FleetConfig(window_s=0.5), controller=controller,
        ).run()
        wall = time.perf_counter() - t0
        s = tel.fleet_summary()
        print(f"{plan_name:12s} {wall:7.2f} {s['requests'] / wall:9.0f} "
              f"{s['p50_ms']:8.1f} {s['p95_ms']:8.1f} {s['p99_ms']:9.1f} "
              f"{100 * s['deadline_miss_rate']:6.1f} "
              f"{100 * s['offload_rate']:6.1f} {s['accuracy']:5.3f} "
              f"{s['controller_switches']:4d}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--controller", action="store_true",
                    help="online re-scoring for the calibrated plan")
    ap.add_argument("--cells", type=int, default=0,
                    help="run at fleet scale through repro.fleet "
                         "(N cells, vectorized; 0 = single-cell event loop)")
    args = ap.parse_args()

    profile = L.paper_2020()
    p_tar = 0.85
    n_val = n_test = 4096
    val_exits, val_final, val_y = synthetic_exit_logits(n_val, seed=0)
    test_exits, test_final, test_y = synthetic_exit_logits(n_test, seed=1)

    plans = {}
    for name, calibrated in (("conventional", False), ("calibrated", True)):
        plans[name] = make_plan(
            [val_exits[1], val_exits[2]], val_y, p_tar=p_tar,
            calibrated=calibrated,
        )
    print(f"fitted temperatures (calibrated): "
          f"{[round(t, 2) for t in plans['calibrated'].temperatures]}  "
          f"p_tar={p_tar}")

    if args.cells > 0:
        run_fleet_scale(args, profile, p_tar, plans, test_exits, test_final,
                        test_y, val_exits, val_final, val_y)
        return

    print(f"\n{'net':7s} {'rate':>5s} {'plan':12s} {'p50ms':>8s} {'p95ms':>8s} "
          f"{'p99ms':>8s} {'miss%':>6s} {'offl%':>6s} {'acc':>5s} {'sw':>3s}")
    for net_name, make_net in networks(profile).items():
        for rate_hz in (20, 60, 120):
            for plan_name, plan in plans.items():
                core = LogitsCore(test_exits, test_final, plan, labels=test_y)
                reqs = poisson_workload(
                    rate_hz, args.requests, n_test, deadline_s=0.1, seed=11
                )
                controller = None
                if args.controller and plan_name == "calibrated":
                    controller = OnlineController(
                        plan, profile, val_exits, final_logits=val_final,
                        labels=val_y,
                        config=ControllerConfig(
                            interval_s=1.0, window_s=2.0,
                            p_tar_grid=(0.5, 0.7, p_tar),
                            min_accuracy=0.9,
                        ),
                    )
                rt = ServingRuntime(
                    core, profile, plan, reqs, network=make_net(),
                    config=RuntimeConfig(max_batch=8, batch_window_s=0.02),
                    controller=controller,
                )
                s = rt.run().summary()
                print(
                    f"{net_name:7s} {rate_hz:5d} {plan_name:12s} "
                    f"{s['p50_ms']:8.1f} {s['p95_ms']:8.1f} {s['p99_ms']:8.1f} "
                    f"{100 * s['deadline_miss_rate']:6.1f} "
                    f"{100 * s['offload_rate']:6.1f} {s['accuracy']:5.3f} "
                    f"{s['controller_switches']:3d}"
                )
    print(
        "\nreading the table: the conventional (overconfident) plan keeps"
        "\nmore samples on-device -- low latency, degraded accuracy; the"
        "\ncalibrated plan refuses unreliable exits, which holds accuracy"
        "\nbut makes it sensitive to the link. Under markov/trace regimes"
        "\nat high arrival rates its tail latency collapses unless the"
        "\nonline controller (--controller) re-scores the partition."
    )


if __name__ == "__main__":
    main()
