"""Quickstart: the paper's pipeline end-to-end on CPU in ~2 minutes.

1. Train a small early-exit B-AlexNet on the synthetic CIFAR-10 stand-in
   (reduced data for speed -- benchmarks/ uses the full 45k/3k/7k split).
2. Show the side branch is overconfident (ECE, reliability diagram).
3. Fit Temperature Scaling on the validation split (paper Eq. 2) and bundle
   it into an OffloadPlan -- then serialize the plan to JSON and reload it,
   verifying the reloaded plan gates bit-identically (the deployable
   artifact IS the calibration pass).
4. Compare the conventional (identity) vs calibrated plan:
   on-device rate, device accuracy vs p_tar, inference outage.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    OffloadPlan,
    ece,
    fit_temperature,
    inference_outage_probability,
    make_plan,
)
from repro.core.exits import gate_statistics
from repro.core.metrics import device_statistics
from repro.data.synthetic import cifar_like
from repro.models import convnet
from repro.models.convnet import B_ALEXNET
from repro.training import optim
from repro.training.loop import make_train_step


def main():
    print("== 1. train early-exit B-AlexNet (reduced synthetic CIFAR) ==")
    data = cifar_like(n_train=8_000, n_val=1_500, n_test=4_000, seed=0)
    params = convnet.init_params(jax.random.PRNGKey(0))
    opt_cfg = optim.AdamWConfig(lr=2e-3, weight_decay=1e-4, total_steps=250)
    step = jax.jit(make_train_step(B_ALEXNET, opt_cfg, remat=False))
    state = optim.init(params)
    rng = np.random.default_rng(0)
    for epoch in range(4):
        order = rng.permutation(len(data.train_y))
        for s in range(0, len(order) - 128 + 1, 128):
            idx = order[s : s + 128]
            batch = {
                "images": jnp.asarray(data.train_x[idx]),
                "labels": jnp.asarray(data.train_y[idx]),
            }
            params, state, m = step(params, state, batch)
        print(f"  epoch {epoch}: loss={float(m['loss']):.3f}")

    infer = jax.jit(lambda x: convnet.forward(params, x))

    def logits_of(x):
        outs = [infer(jnp.asarray(x[s : s + 512])) for s in range(0, len(x), 512)]
        return (
            np.concatenate([np.asarray(o["exit_logits"][0]) for o in outs]),
            np.concatenate([np.asarray(o["logits"]) for o in outs]),
        )

    vb1, _ = logits_of(data.val_x)
    tb1, tmain = logits_of(data.test_x)

    print("\n== 2. miscalibration of the side branch ==")
    conf, pred, _ = gate_statistics(tb1, 1.0)
    correct = np.asarray(pred) == data.test_y
    print(f"  branch-1 accuracy:        {correct.mean():.3f}")
    print(f"  branch-1 mean confidence: {np.asarray(conf).mean():.3f}")
    print(f"  branch-1 ECE:             {ece(np.asarray(conf), correct):.3f}")

    print("\n== 3. temperature scaling -> OffloadPlan -> JSON round-trip ==")
    T, info = fit_temperature(jnp.asarray(vb1), jnp.asarray(data.val_y))
    print(f"  T = {float(T):.3f}  (NLL {float(info['nll_before']):.3f} -> "
          f"{float(info['nll_after']):.3f})")
    confT, _, _ = gate_statistics(tb1, float(T))
    print(f"  calibrated ECE:           {ece(np.asarray(confT), correct):.3f}")

    plan = make_plan([jnp.asarray(vb1)], jnp.asarray(data.val_y), p_tar=0.85)
    blob = plan.to_json()
    reloaded = OffloadPlan.from_json(blob)
    g0 = plan.gate(jnp.asarray(tb1))
    g1 = reloaded.gate(jnp.asarray(tb1))
    same = bool(np.array_equal(np.asarray(g0.exit_mask), np.asarray(g1.exit_mask)))
    print(f"  plan JSON = {len(blob)} bytes; reloaded gate decisions "
          f"bit-identical: {same}")

    print("\n== 4. offloading plans (paper Figs. 2/3b/4) ==")
    conv = make_plan([jnp.asarray(vb1)], jnp.asarray(data.val_y),
                     p_tar=0.85, calibrated=False)
    print("  p_tar | on-device%  conv/cal | device-acc conv/cal | outage conv/cal")
    for p_tar in (0.75, 0.85, 0.9):
        sc = device_statistics(tb1, data.test_y, p_tar, conv.temperatures[0])
        sk = device_statistics(tb1, data.test_y, p_tar, plan.temperatures[0])
        oc = inference_outage_probability(
            tb1, data.test_y, p_tar, conv.temperatures[0], batch_size=256
        )
        ok = inference_outage_probability(
            tb1, data.test_y, p_tar, plan.temperatures[0], batch_size=256
        )
        print(
            f"  {p_tar:.3f} |   {float(sc['on_device_prob']):.2f} / "
            f"{float(sk['on_device_prob']):.2f}    |     {float(sc['device_accuracy']):.3f} / "
            f"{float(sk['device_accuracy']):.3f}   |  {oc:.2f} / {ok:.2f}"
        )
    print("\ncalibrated gates keep fewer samples on-device but meet p_tar;"
          "\nconventional gates overcommit and miss the target (the paper's point).")


if __name__ == "__main__":
    main()
