"""Distortion-robust offloading, end to end on a REAL trained model.

Pacheco et al. (2108.09343): an early-exit DNN calibrated once on clean
validation data breaks when inputs arrive blurred or noisy. Miscalibration
under drift has two directions, and a single clean-fit temperature is
wrong in both:

* OVERconfident drift (Pacheco's nets): accuracy collapses while the head
  stays confident -- the gate keeps misclassified samples on-device and
  silently misses p_tar. The synthetic drift scenario and the CI-asserted
  BENCH_distortion.json exercise this direction.
* UNDERconfident drift (this example's model, trained with noise
  augmentation on the smooth-template task): blur/contrast shrink the
  logit magnitudes faster than they destroy the class evidence, so raw
  accuracy barely moves while confidence evaporates -- the clean-fit gate
  starves the edge (on-device rate -> 0), saturates the uplink, and blows
  up tail latency for NO reliability gain. Expert temperatures here are
  <1 (sharpening), the mirror image of Pacheco's >1 experts.

The fix is the same for both: a bank of per-distortion *expert*
calibrators plus a cheap edge-side estimator that recognizes the current
distortion from input statistics (Laplacian variance + pixel moments --
no extra DNN).

This example runs the whole pipeline on a trained model (no synthetic
logits anywhere):

1. train a small early-exit B-AlexNet on the synthetic CIFAR stand-in;
2. distort the validation/test splits with the parametric taxonomy
   (`repro.data.distortion`) at the reference contexts;
3. fit the single global plan (clean val only, the paper's procedure) and
   the expert `PlanBank` (one plan per context + estimator), and round-trip
   the bank through JSON -- the whole bank is ONE deployable artifact;
4. compare them offline per context, then under a drifting Markov severity
   schedule in the event-driven serving runtime, where each request's
   expert is chosen by the estimator from that sample's REAL distorted
   image statistics.

Run:  PYTHONPATH=src python examples/offload_under_distortion.py
      [--epochs 3] [--requests 1200]

With --cells N step 5 runs at FLEET scale instead: the trained model's
per-context logits serve N cells at once through the vectorized
`repro.fleet` simulator, each cell under its OWN Markov severity drift
(weather is not synchronized across sites) behind one shared cloud --
the same comparison, hundreds of thousands of requests in seconds.

      PYTHONPATH=src python examples/offload_under_distortion.py --cells 64

With --compression {1,2} the deployed plans -- the global plan AND every
expert in the bank (`PlanBank.with_compression`) -- ship refused payloads
through the bottleneck codec (`repro.kernels.compress`: per-tile absmax
int8/int4) instead of raw float32, cutting uplink bytes ~3.9x/7.5x under
the same Markov drift; both serving paths price the wire bytes.

      PYTHONPATH=src python examples/offload_under_distortion.py --compression 2
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PlanBank, fit_bank, make_plan
from repro.core.exits import gate_statistics
from repro.data.distortion import DistortionSpec, apply_distortion, input_features
from repro.data.synthetic import cifar_like
from repro.models import convnet
from repro.models.convnet import B_ALEXNET
from repro.serving.drift import ContextualLogitsCore, MarkovContextSchedule
from repro.serving.runtime import RuntimeConfig, ServingRuntime
from repro.offload import latency as L
from repro.serving.workload import poisson_workload

P_TAR = 0.8


def train(data, epochs):
    from repro.training import optim
    from repro.training.loop import make_train_step

    params = convnet.init_params(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        B_ALEXNET, optim.AdamWConfig(lr=2e-3, weight_decay=1e-4,
                                     total_steps=80 * epochs),
        remat=False,
    ))
    state = optim.init(params)
    rng = np.random.default_rng(0)
    for epoch in range(epochs):
        order = rng.permutation(len(data.train_y))
        for s in range(0, len(order) - 128 + 1, 128):
            idx = order[s : s + 128]
            batch = {"images": jnp.asarray(data.train_x[idx]),
                     "labels": jnp.asarray(data.train_y[idx])}
            params, state, m = step(params, state, batch)
        print(f"  epoch {epoch}: loss={float(m['loss']):.3f}")
    return params


def logits_of(params, x, bs=512):
    infer = jax.jit(lambda b: convnet.forward(params, b))
    outs = [infer(jnp.asarray(x[s : s + bs])) for s in range(0, len(x), bs)]
    return (
        np.concatenate([np.asarray(o["exit_logits"][0]) for o in outs]),
        np.concatenate([np.asarray(o["exit_logits"][1]) for o in outs]),
        np.concatenate([np.asarray(o["logits"]) for o in outs]),
    )


def per_context_data(params, x, contexts, seed):
    """Push each context's REALLY distorted images through the model."""
    out = {"exit_logits": {}, "final": {}, "features": {}}
    for spec in contexts:
        xd = apply_distortion(x, spec, seed=seed)
        z1, z2, zf = logits_of(params, xd)
        out["exit_logits"][spec.key] = {1: z1, 2: z2}
        out["final"][spec.key] = zf
        out["features"][spec.key] = input_features(xd)
    return out


def offline_table(name, plan_of, test, labels):
    print(f"  {name}: context            | on-device%  | device-acc | gap")
    for ctx in sorted(test["exit_logits"]):
        plan = plan_of(ctx)
        z = test["exit_logits"][ctx][1]
        conf, pred, _ = gate_statistics(plan.calibrated_logits(z, 0))
        conf, pred = np.asarray(conf), np.asarray(pred)
        on = conf >= plan.p_tar
        acc = (pred[on] == labels[on]).mean() if on.sum() else float("nan")
        print(f"    {ctx:18s} |    {on.mean():.2f}     |   {acc:.3f}    | "
              f"{abs(acc - plan.p_tar):.3f}")


def serve_fleet(n_cells, n_requests, contexts, test, labels, plans, profile):
    """The --cells fast path: N drifting cells, one shared cloud, served
    by the vectorized fleet simulator."""
    import time

    from repro.fleet import (
        CellConfig,
        FleetConfig,
        FleetGateTable,
        FleetSimulator,
        FleetTopology,
    )
    from repro.fleet.topology import poisson_cell_workload
    from repro.serving.network import FixedRateNetwork

    keys = [spec.key for spec in contexts]
    cells = [
        CellConfig(
            network=FixedRateNetwork(profile.uplink_bps),
            workload=poisson_cell_workload(
                40.0, n_requests, len(labels), n_devices=2, seed=200 + i
            ),
            n_devices=2,
            schedule=MarkovContextSchedule(
                keys, dwell_s=3.0, p_stay=0.5, seed=10 + i,
                start_context="clean",
            ),
            deadline_s=0.1,
        )
        for i in range(n_cells)
    ]
    topology = FleetTopology(cells, cloud_servers=4)
    print(f"  {n_cells} cells x {n_requests} requests = "
          f"{topology.n_requests} total, per-cell Markov severity drift")
    for name, deployed in plans:
        table = FleetGateTable(
            test["exit_logits"], test["final"], deployed,
            labels=labels, features_by_context=test["features"],
        )
        t0 = time.perf_counter()
        tel = FleetSimulator(table, topology, profile,
                             config=FleetConfig(window_s=0.5)).run()
        wall = time.perf_counter() - t0
        s = tel.fleet_summary()
        print(f"  {name}: {s['requests'] / wall:.0f} req/s simulated; "
              f"miscal gap={s['miscalibration_gap']:.3f} "
              f"acc={s['accuracy']:.3f} offload={s['offload_rate']:.2f} "
              f"p99={s['p99_ms']:.0f}ms")
        for ctx, row in tel.per_context_summary().items():
            print(f"      {ctx:18s} gap={row['miscalibration_gap']:.3f} "
                  f"ondev_acc={row['on_device_accuracy']:.3f} "
                  f"offl={row['offload_rate']:.2f} "
                  f"est={row['est_match_rate']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--requests", type=int, default=1200)
    ap.add_argument("--cells", type=int, default=0,
                    help="serve step 5 at fleet scale through repro.fleet "
                         "(N drifting cells; 0 = single-cell event loop)")
    ap.add_argument("--compression", type=int, default=0, choices=(0, 1, 2),
                    help="payload codec level for the deployed plans "
                         "(repro.kernels.compress: 0 = raw float32, the "
                         "paper's pricing; 1 = int8; 2 = int4)")
    args = ap.parse_args()

    print("== 1. train early-exit B-AlexNet (reduced synthetic CIFAR) ==")
    data = cifar_like(n_train=8_000, n_val=1_500, n_test=3_000, seed=0)
    params = train(data, args.epochs)

    print("\n== 2. distort val/test splits at the reference contexts ==")
    # harsher than scenarios.drift_contexts(): this model shrugs off mild
    # distortion, and the interesting regime is where the clean-fit plan
    # visibly starves the edge
    contexts = [
        DistortionSpec("clean"),
        DistortionSpec("gaussian_noise", 4),
        DistortionSpec("gaussian_blur", 4),
        DistortionSpec("contrast", 3),
    ]
    print("  contexts:", [spec.key for spec in contexts])
    val = per_context_data(params, data.val_x, contexts, seed=1)
    test = per_context_data(params, data.test_x, contexts, seed=2)
    val["labels"], test["labels"] = data.val_y, data.test_y

    print("\n== 3. fit global plan (clean only) vs expert PlanBank ==")
    clean = val["exit_logits"]["clean"]
    y = jnp.asarray(data.val_y)
    global_plan = make_plan([clean[1], clean[2]], y, p_tar=P_TAR)
    bank = fit_bank(
        {ctx: [z[1], z[2]] for ctx, z in val["exit_logits"].items()},
        y, p_tar=P_TAR, default_context="clean",
        features_by_context=val["features"],
    )
    bank = PlanBank.from_json(bank.to_json())  # one JSON artifact, reloaded
    print(f"  global T1={global_plan.temperatures[0]:.2f}; experts:",
          {ctx: round(p.temperatures[0], 2) for ctx, p in bank.plans.items()})
    if args.compression:
        # the codec knob composes with the bank: every expert keeps its
        # calibrator, only the wire format of refused payloads changes
        global_plan = global_plan.with_compression(args.compression)
        bank = bank.with_compression(args.compression)
        for b in (1, 2):
            print(f"  codec level {args.compression}: branch-{b} payload "
                  f"{L.payload_bytes_for(b)} -> "
                  f"{L.payload_bytes_for(b, args.compression)} bytes/request")

    print("\n== 4. offline per-context reliability at p_tar =", P_TAR, "==")
    offline_table("global plan", lambda ctx: global_plan, test, data.test_y)
    offline_table("expert bank", bank.plan_for, test, data.test_y)

    print("\n== 5. serving under a drifting Markov severity schedule ==")
    profile = L.paper_2020()
    if args.cells > 0:
        serve_fleet(
            args.cells, args.requests, contexts, test, data.test_y,
            [("global plan", global_plan), ("expert bank", bank)], profile,
        )
        return
    schedule = MarkovContextSchedule(
        [spec.key for spec in contexts], dwell_s=3.0, p_stay=0.5, seed=10,
        start_context="clean",
    )
    for name, deployed in (("global plan", global_plan), ("expert bank", bank)):
        core = ContextualLogitsCore(
            test["exit_logits"], test["final"], deployed, schedule,
            labels=data.test_y, features_by_context=test["features"],
        )
        reqs = poisson_workload(40.0, args.requests, core.n_samples,
                                deadline_s=0.1, seed=7)
        tel = ServingRuntime(
            core, profile, deployed, reqs,
            config=RuntimeConfig(max_batch=4, batch_window_s=0.02),
        ).run()
        s = tel.summary()
        print(f"  {name}: miscal gap={s['miscalibration_gap']:.3f} "
              f"acc={s['accuracy']:.3f} offload={s['offload_rate']:.2f} "
              f"p99={s['p99_ms']:.0f}ms")
        for ctx, row in tel.per_context_summary().items():
            print(f"      {ctx:18s} gap={row['miscalibration_gap']:.3f} "
                  f"ondev_acc={row['on_device_accuracy']:.3f} "
                  f"offl={row['offload_rate']:.2f} "
                  f"est={row['est_match_rate']:.2f}")

    print("\nthis model drifts UNDERconfident: the clean-fit plan starves the"
          "\nedge under blur/contrast (on-device -> 0, uplink saturated, p99"
          "\nblown up) at no reliability gain, while the expert bank keeps"
          "\n~80% of traffic on-device at the same accuracy by re-sharpening"
          "\nper regime. The OVERconfident direction (accuracy collapse behind"
          "\na confident gate -- Pacheco et al., 2108.09343) is exercised by"
          "\nthe synthetic drift scenario in BENCH_distortion.json. One"
          "\nclean-fit temperature cannot serve both; a PlanBank serves each"
          "\nregime with the calibrator fit for it.")


if __name__ == "__main__":
    main()
